"""Exact integer-accumulation budgets for the low-precision tap lane.

The paper's "operator transformation" trick restructures the taps to cut
arithmetic; the orthogonal precision trick is that a u8 frame correlated
with *integer* taps never needs floating point at all: every intermediate
the variant ladder materializes is an exact integer bounded by
``input_max * sum(|taps|)``, so the whole gradient stage can run in
i16/i32 and convert to f32 only at the magnitude/NMS boundary — and the
result is *bit-identical* to the f32 lane, because both lanes compute the
same exact integers (f32 holds every integer up to 2^24 exactly).

This module is the single source of those budgets. It is shared by:

  * the static analyzer (``repro.analysis.rules`` DTYPE001), which proves
    the budget per registered operator and — since the integer lane landed
    — checks the traced kernel's *actual* accumulation dtype against it;
  * the dispatcher (``repro.kernels.dispatch.resolve_precision``), which
    gates ``EdgeConfig.precision="auto"|"int"`` on the same proof;
  * the kernels (``repro.kernels.edge``), which pick the accumulation
    dtype from :func:`accum_dtype`.

Bound derivation (why ``worst`` is what it is): per direction the final
response is ``sum_t taps[t] * x[t]`` with ``0 <= x <= input_max``, so
``|response| <= input_max * sum|taps|``. Partial sums and the separable
row/column passes are bounded by the same triangle inequality (a partial
sum omits terms; a row-pass intermediate times a column tap is one term
of the dense expansion). The v1/v2 operator transform additionally forms
``gd_plus = gd + gdt`` and ``gd_minus = gd - gdt`` (Eq. 10-11), so for
4-direction banks the binding bound is the *pairwise* one — the two
largest per-direction bounds added. The halving in ``gd = (gd_plus +
gd_minus) / 2`` is exact in integers because the sum is ``2 * gd`` (even
by construction); the kernels spell it as an arithmetic right shift.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

__all__ = [
    "F32_EXACT_INT",
    "tap_accumulation_bounds",
    "accum_dtype",
    "int_lane_eligible",
    "plan_input_bound",
    "plan_int_eligible",
    "plan_accum_dtype",
]

# Exact-representation ceilings for the dtype ladder.
F32_EXACT_INT = 2**24
_I16_MAX = 2**15 - 1
_I32_MAX = 2**31 - 1


def tap_accumulation_bounds(spec, *, input_max: int = 255) -> Dict[str, object]:
    """Worst-case accumulation magnitude of ``input_max``-bounded input
    against the spec's dense filter bank.

    Per direction the bound is ``input_max * sum(|taps|)``; for
    4-direction operators the v2 operator-transform path combines two
    directional kernels (kd ± kdᵀ), so the pairwise bound — the two
    largest per-direction sums added — covers every intermediate either
    variant materializes. Gradients only: the NMS magnitude stays f32 by
    contract and is not part of the integer ladder.
    """
    bank = spec.bank(max(spec.directions))
    integer = bool(np.all(bank == np.round(bank)))
    per_dir = [float(input_max * np.abs(k).sum()) for k in bank]
    worst = max(per_dir)
    if len(per_dir) >= 4:
        worst = sum(sorted(per_dir)[-2:])
    return {
        "integer_taps": integer,
        "per_direction": per_dir,
        "worst": worst,
        "fits_i16": worst <= _I16_MAX,
        "fits_i32": worst <= _I32_MAX,
        "f32_exact": worst <= F32_EXACT_INT,
    }


def accum_dtype(spec, *, input_max: int = 255) -> Optional[str]:
    """Narrowest exact integer accumulation dtype for the spec, or None.

    Returns ``"int16"``/``"int32"`` when the integer lane is provably
    bit-exact against the f32 lane for ``input_max``-bounded (u8) input,
    else ``None``. Three conditions, all from the same
    :func:`tap_accumulation_bounds` computation DTYPE001 checks:

      * integer taps — fractional taps have no exact integer form;
      * the bound fits the candidate integer dtype (no wraparound);
      * the bound fits f32's exact-integer range (≤ 2^24) — without this
        the *f32* lane itself rounds, so "bit-identical by construction"
        would have nothing exact to be identical to.
    """
    b = tap_accumulation_bounds(spec, input_max=input_max)
    if not b["integer_taps"] or not b["f32_exact"]:
        return None
    if b["fits_i16"]:
        return "int16"
    if b["fits_i32"]:
        return "int32"
    return None


def int_lane_eligible(
    spec, *, rgb: bool, input_dtype=None, input_max: int = 255
) -> Tuple[bool, str]:
    """(eligible, reason) for running the exact integer lane.

    ``reason`` explains the *first* failing gate when ineligible (used
    verbatim in the ``precision="int"`` error message). RGB input is
    ineligible by design: the BT.601 luma weights are fractional, and the
    f32 reference computes ``0.299*R + 0.587*G + 0.114*B`` with fenced f32
    roundings that no fixed-point formulation reproduces bit-for-bit
    (DESIGN.md §11 derives the 16-bit fixed-point luma and shows where it
    diverges) — so an integer lane on RGB could be fast but never exact.
    """
    if rgb:
        return False, (
            "RGB input needs the fractional BT.601 luma, whose fenced f32 "
            "rounding has no bit-exact fixed-point equivalent"
        )
    if input_dtype is not None and np.dtype(input_dtype) != np.dtype(np.uint8):
        return False, (
            f"input dtype {np.dtype(input_dtype).name} is not uint8 — the "
            "integer bound only covers [0, 255] integer frames"
        )
    b = tap_accumulation_bounds(spec, input_max=input_max)
    if not b["integer_taps"]:
        return False, f"operator {spec.name!r} has fractional taps"
    if not b["f32_exact"]:
        return False, (
            f"accumulation bound {b['worst']:.0f} exceeds f32's exact "
            "integer range (2^24); the f32 lane itself rounds"
        )
    if not b["fits_i32"]:
        return False, (
            f"accumulation bound {b['worst']:.0f} exceeds i32"
        )
    return True, ""


# ---------------------------------------------------------------------------
# StencilPlan extension: chain the bound through every pre-stage, then
# apply the per-operator proof above to the gradient stage with the
# chained input bound. A one-gradient-stage plan reduces exactly to
# ``int_lane_eligible(spec)``.
# ---------------------------------------------------------------------------

def plan_input_bound(plan, *, input_max: int = 255):
    """(bound, reason) — the gradient stage's input magnitude bound after
    the plan's pre-stages, or (None, reason) when a pre-stage leaves the
    integer lane. ``reason`` names the failing gate (used verbatim in the
    ``precision="int"`` error message).

    Per stage kind: window max/min selects an input value (bound
    preserved); an integer-tap linear stage multiplies the bound by
    ``sum|taps|`` (triangle inequality, same as the gradient proof); a
    fractional-tap stage (the normalized Gaussians) has no exact integer
    form; pointwise fns carry their own registered bound transform.
    """
    from repro.core import filters as F

    m = float(input_max)
    for stage in plan.pre_stages:
        if stage.kind == "window_reduce":
            continue
        if stage.kind == "linear":
            bank = stage.operator.bank(1)
            if not np.all(bank == np.round(bank)):
                return None, (
                    f"plan gate 'integer-taps': stage {stage.name!r} has "
                    "fractional taps (no exact integer form)"
                )
            m = m * float(np.abs(bank[0]).sum())
        elif stage.kind == "pointwise":
            _fn, bound = F.get_pointwise(stage.op)
            if bound is None:
                return None, (
                    f"plan gate 'integer-taps': pointwise stage "
                    f"{stage.name!r} has no integer bound transform"
                )
            m = float(bound(m))
        if m > F32_EXACT_INT:
            return None, (
                f"plan gate 'integer-taps': bound {m:.0f} after stage "
                f"{stage.name!r} exceeds f32's exact integer range (2^24)"
            )
    return m, ""


def plan_int_eligible(
    plan, *, rgb: bool, input_dtype=None, input_max: int = 255
) -> Tuple[bool, str]:
    """Plan-level (eligible, reason) for the exact integer lane."""
    spec = plan.gradient
    if spec is None:
        return False, (
            f"plan {plan.name!r} has no gradient stage; the integer lane "
            "covers gradient plans only"
        )
    if not plan.pre_stages:
        return int_lane_eligible(
            spec, rgb=rgb, input_dtype=input_dtype, input_max=input_max
        )
    if rgb:
        return False, (
            "RGB input needs the fractional BT.601 luma, whose fenced f32 "
            "rounding has no bit-exact fixed-point equivalent"
        )
    if input_dtype is not None and np.dtype(input_dtype) != np.dtype(np.uint8):
        return False, (
            f"input dtype {np.dtype(input_dtype).name} is not uint8 — the "
            "integer bound only covers [0, 255] integer frames"
        )
    m, reason = plan_input_bound(plan, input_max=input_max)
    if m is None:
        return False, reason
    b = tap_accumulation_bounds(spec, input_max=m)
    if not b["integer_taps"]:
        return False, f"operator {spec.name!r} has fractional taps"
    if not b["f32_exact"]:
        return False, (
            f"accumulation bound {b['worst']:.0f} exceeds f32's exact "
            "integer range (2^24); the f32 lane itself rounds"
        )
    if not b["fits_i32"]:
        return False, f"accumulation bound {b['worst']:.0f} exceeds i32"
    return True, ""


def plan_accum_dtype(plan, *, input_max: int = 255) -> Optional[str]:
    """Narrowest exact integer accumulation dtype for the whole plan."""
    spec = plan.gradient
    if spec is None:
        return None
    if not plan.pre_stages:
        return accum_dtype(spec, input_max=input_max)
    m, _reason = plan_input_bound(plan, input_max=input_max)
    if m is None:
        return None
    b = tap_accumulation_bounds(spec, input_max=m)
    if not b["integer_taps"] or not b["f32_exact"]:
        return None
    if b["fits_i16"]:
        return "int16"
    if b["fits_i32"]:
        return "int32"
    return None
