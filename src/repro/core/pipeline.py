"""End-to-end edge-detection pipeline (the paper's full workload).

gray conversion -> padding -> multi-directional Sobel -> RSS magnitude ->
normalization, batched over images, optionally sharded over a device mesh
(batch -> data axes, image rows -> model axis).

This is also registered as the ``sobel_hd`` architecture for the dry-run:
``serve_step`` = one batched edge-detection pass.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.filters import SobelParams

__all__ = ["rgb_to_gray", "edge_detect", "make_sharded_edge_fn"]

# ITU-R BT.601 luma weights (OpenCV cvtColor convention).
_LUMA = (0.299, 0.587, 0.114)


def rgb_to_gray(images: jnp.ndarray) -> jnp.ndarray:
    """(..., H, W, 3) uint8/float -> (..., H, W) float32 grayscale."""
    x = images.astype(jnp.float32)
    return _LUMA[0] * x[..., 0] + _LUMA[1] * x[..., 1] + _LUMA[2] * x[..., 2]


def edge_detect(
    images: jnp.ndarray,
    *,
    size: int = 5,
    directions: int = 4,
    variant: str = "v2",
    params: SobelParams = SobelParams(),
    padding: str = "reflect",
    normalize: bool = True,
    backend: Optional[str] = None,
    block_h: Optional[int] = None,
    block_w: Optional[int] = None,
) -> jnp.ndarray:
    """Full pipeline on a batch of images.

    Args:
      images: ``(..., H, W)`` grayscale or ``(..., H, W, 3)`` RGB.
      normalize: scale magnitudes into [0, 255] (per image) and saturate —
        the display form used for the paper's Fig. 1/7 outputs.
      backend: ``repro.kernels.dispatch`` backend (``auto`` / ``pallas-tpu``
        / ``pallas-interpret`` / ``xla``); None = auto.
      block_h, block_w: Pallas tile override; None = tuning cache / default.
    Returns:
      ``(..., H, W)`` float32 edge image.
    """
    # Imported here: repro.core must stay importable without repro.kernels
    # (kernels itself builds on repro.core.sobel).
    from repro.kernels.dispatch import sobel as dispatch_sobel

    if images.ndim >= 3 and images.shape[-1] == 3:
        gray = rgb_to_gray(images)
    else:
        gray = images.astype(jnp.float32)
    g = dispatch_sobel(
        gray,
        size=size,
        directions=directions,
        variant=variant,
        params=params,
        padding=padding,
        backend=backend,
        block_h=block_h,
        block_w=block_w,
    )
    if normalize:
        peak = jnp.max(g, axis=(-2, -1), keepdims=True)
        g = g * (255.0 / jnp.maximum(peak, 1e-8))
    return g


def make_sharded_edge_fn(
    mesh: Mesh,
    *,
    batch_axes=("data",),
    row_axis: Optional[str] = "model",
    size: int = 5,
    directions: int = 4,
    variant: str = "v2",
    params: SobelParams = SobelParams(),
    backend: Optional[str] = None,
):
    """jit-compiled edge detector with batch sharded over ``batch_axes`` and
    image rows over ``row_axis`` (GSPMD inserts the 2r-row halo exchange).

    Returns ``fn(images: (N, H, W) or (N, H, W, 3)) -> (N, H, W)``.
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    row = row_axis if (row_axis and row_axis in mesh.axis_names) else None
    in_spec = P(batch_axes if batch_axes else None, row)
    out_spec = P(batch_axes if batch_axes else None, row)

    def fn(images):
        return edge_detect(
            images,
            size=size,
            directions=directions,
            variant=variant,
            params=params,
            normalize=False,
            backend=backend,
        )

    return jax.jit(
        fn,
        in_shardings=NamedSharding(mesh, in_spec),
        out_shardings=NamedSharding(mesh, out_spec),
    )
