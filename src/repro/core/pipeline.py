"""End-to-end edge-detection pipeline (the paper's full workload).

gray conversion -> in-kernel boundary handling -> multi-directional Sobel ->
RSS magnitude -> normalization, batched over images, optionally sharded over
a device mesh (batch -> data axes, image rows -> model axis).

On the Pallas backends the whole chain is ONE fused zero-copy kernel launch
(``repro.api.edge_detect``): the raw u8 frame is read from HBM
exactly once, luma and padding happen per-tile in VMEM, and normalization
rides on per-block maxima emitted by the kernel. The ``xla`` backend keeps
the legacy multi-pass pipeline; outputs are bit-exact across backends.

This is also registered as the ``sobel_hd`` architecture for the dry-run:
``serve_step`` = one batched edge-detection pass. The historical
``edge_detect`` kwargs shim that lived here was removed with the
stencil-platform refactor — use :func:`repro.api.edge_detect`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["rgb_to_gray", "make_sharded_edge_fn"]

# ITU-R BT.601 luma weights (OpenCV cvtColor convention).
_LUMA = (0.299, 0.587, 0.114)


def rgb_to_gray(images: jnp.ndarray) -> jnp.ndarray:
    """(..., H, W, 3) uint8/float -> (..., H, W) float32 grayscale.

    Each product is passed through ``maximum(w * c, -FLT_MAX)`` — an exact
    identity for every finite value (negative channels included) that the
    XLA algebraic simplifier cannot fold — so XLA cannot contract the
    multiplies into FMAs. Without it, jit-fused XLA and the Pallas
    megakernel (which computes the same luma per-tile in VMEM, see
    ``repro.kernels.tiling.luma``) round a small fraction of pixels 1 ulp
    apart, breaking cross-backend bit-exactness — the same FMA-proofing trick
    as ``repro.core.sobel._tap`` / ``magnitude``.
    """
    from repro.core.sobel import _F32_LOWEST

    x = images.astype(jnp.float32)
    lo = jnp.float32(_F32_LOWEST)
    return (
        jnp.maximum(_LUMA[0] * x[..., 0], lo)
        + jnp.maximum(_LUMA[1] * x[..., 1], lo)
    ) + jnp.maximum(_LUMA[2] * x[..., 2], lo)


def make_sharded_edge_fn(
    mesh: Mesh,
    config=None,
    *,
    batch_axes=("data",),
    row_axis: Optional[str] = "model",
    **config_overrides,
):
    """jit-compiled edge detector with batch sharded over ``batch_axes`` and
    image rows over ``row_axis`` (GSPMD inserts the 2r-row halo exchange).

    ``config`` is an :class:`~repro.api.EdgeConfig` (defaults to an
    unnormalized Sobel-5x5 pass); ``config_overrides`` are field overrides,
    including the legacy ``size=`` selector. Returns
    ``fn(images: (N, H, W) or (N, H, W, 3)) -> (N, H, W)`` magnitude.
    """
    from repro.api import EdgeConfig, edge_detect as api_edge_detect
    from repro.core.filters import operator_for_size

    size = config_overrides.pop("size", None)
    cfg = config or EdgeConfig(normalize=False)
    if size is not None:
        cfg = cfg.replace(operator=operator_for_size(size))
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    cfg = cfg.resolved()

    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    row = row_axis if (row_axis and row_axis in mesh.axis_names) else None
    in_spec = P(batch_axes if batch_axes else None, row)
    out_spec = P(batch_axes if batch_axes else None, row)

    def fn(images):
        return api_edge_detect(images, cfg).magnitude

    return jax.jit(
        fn,
        in_shardings=NamedSharding(mesh, in_spec),
        out_shardings=NamedSharding(mesh, out_spec),
    )
