"""Core library: the paper's multi-directional Sobel operator + the
declarative operator registry (``OperatorSpec``)."""
from repro.core.filters import (  # noqa: F401
    OperatorSpec,
    SobelParams,
    Stage,
    StencilPlan,
    get_operator,
    get_plan,
    get_stage,
    list_operators,
    list_plans,
    list_stages,
    make_plan,
    make_separable_spec,
    plan_identity,
    register_operator,
    register_plan,
    register_pointwise,
    register_stage,
    resolve_plan,
    filter_bank_3x3,
    filter_bank_5x5,
    kd,
    kd_minus,
    kd_minus_factors,
    kd_plus,
    kd_plus_rows,
    kdt,
    kx,
    kx_factors,
    ky,
    ky_factors,
)
from repro.core.nms import (  # noqa: F401
    hysteresis,
    nms_sector,
    nms_thin,
    resolve_thresholds,
    thin_map,
)
from repro.core.pipeline import make_sharded_edge_fn, rgb_to_gray  # noqa: F401
from repro.core.sobel import VARIANTS, magnitude, sobel, sobel_components  # noqa: F401
from repro.core.ssim import ssim  # noqa: F401
