"""Structural Similarity Index (paper Eq. 20, used for Fig. 7 correctness).

Standard Wang et al. SSIM with an 11x11 Gaussian window (sigma = 1.5),
C1 = (0.01 L)^2, C2 = (0.03 L)^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ssim"]


def _gaussian_window(size: int = 11, sigma: float = 1.5) -> np.ndarray:
    ax = np.arange(size, dtype=np.float64) - (size - 1) / 2.0
    g = np.exp(-(ax ** 2) / (2.0 * sigma ** 2))
    g /= g.sum()
    return g.astype(np.float32)


def _filter2(x: jnp.ndarray, win: np.ndarray) -> jnp.ndarray:
    """Separable valid-mode Gaussian filtering over the last two axes."""
    k = win.shape[0]
    # horizontal
    out_w = x.shape[-1] - k + 1
    acc = None
    for t in range(k):
        term = win[t] * x[..., :, t : t + out_w]
        acc = term if acc is None else acc + term
    x = acc
    # vertical
    out_h = x.shape[-2] - k + 1
    acc = None
    for t in range(k):
        term = win[t] * x[..., t : t + out_h, :]
        acc = term if acc is None else acc + term
    return acc


def ssim(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    data_range: float | None = None,
    win_size: int = 11,
    sigma: float = 1.5,
) -> jnp.ndarray:
    """Mean SSIM between images ``x`` and ``y`` of shape ``(..., H, W)``.

    Returns a scalar per leading batch element (shape ``(...)``).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if data_range is None:
        rng = jnp.maximum(
            jnp.max(x, axis=(-2, -1)) - jnp.min(x, axis=(-2, -1)),
            jnp.max(y, axis=(-2, -1)) - jnp.min(y, axis=(-2, -1)),
        )
        rng = jnp.maximum(rng, 1e-8)[..., None, None]
    else:
        rng = jnp.float32(data_range)

    c1 = (0.01 * rng) ** 2
    c2 = (0.03 * rng) ** 2
    win = _gaussian_window(win_size, sigma)

    mu_x = _filter2(x, win)
    mu_y = _filter2(y, win)
    mu_xx = mu_x * mu_x
    mu_yy = mu_y * mu_y
    mu_xy = mu_x * mu_y
    sigma_xx = _filter2(x * x, win) - mu_xx
    sigma_yy = _filter2(y * y, win) - mu_yy
    sigma_xy = _filter2(x * y, win) - mu_xy

    if data_range is None:
        c1 = c1[..., : mu_x.shape[-2], : mu_x.shape[-1]] * jnp.ones_like(mu_x)
        c2 = c2[..., : mu_x.shape[-2], : mu_x.shape[-1]] * jnp.ones_like(mu_x)

    num = (2.0 * mu_xy + c1) * (2.0 * sigma_xy + c2)
    den = (mu_xx + mu_yy + c1) * (sigma_xx + sigma_yy + c2)
    return jnp.mean(num / den, axis=(-2, -1))


ssim_jit = jax.jit(ssim, static_argnames=("win_size",))
