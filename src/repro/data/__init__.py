from repro.data.loader import DataLoader, batch_shardings  # noqa: F401
from repro.data.synthetic import image_batch, lm_batch  # noqa: F401
