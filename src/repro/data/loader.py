"""Sharded, prefetching, checkpointable data loader.

The loader is a thin deterministic pipeline over ``data.synthetic``:
  * batches are a pure function of (seed, step) -> restoring ``state()``
    resumes the exact stream (required for fault-tolerant restarts);
  * arrays are placed onto the mesh with NamedShardings (batch -> (pod, data));
  * a background thread prefetches ``prefetch`` steps ahead (the host-side
    analogue of the paper's prefetching mechanism: hide H2D latency behind
    compute).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig
from repro.data.synthetic import image_batch, lm_batch
from repro.sharding.rules import logical_to_spec

__all__ = ["DataLoader", "batch_shardings"]

_BATCH_AXES = {
    "tokens": ("batch", None),
    "labels": ("batch", None),
    "loss_weights": ("batch", None),
    "patch_embeds": ("batch", None, None),
    "enc_embeds": ("batch", None, None),
    "images": ("batch", "height", "width"),
}


def batch_shardings(batch: Dict[str, np.ndarray], mesh: Optional[Mesh]):
    if mesh is None:
        return None
    return {
        k: NamedSharding(mesh, logical_to_spec(_BATCH_AXES[k], mesh, v.shape))
        for k, v in batch.items()
    }


class DataLoader:
    """Deterministic prefetching loader; ``state()``/``restore()`` round-trip."""

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq_len: int = 0,
        *,
        mesh: Optional[Mesh] = None,
        seed: int = 0,
        prefetch: int = 2,
        start_step: int = 0,
    ):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self.mesh, self.seed = mesh, seed
        self._step = start_step
        self._prefetch = max(1, prefetch)
        self._q: "queue.Queue" = queue.Queue(maxsize=self._prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- determinism / checkpointing -----------------------------------------
    def state(self) -> Dict:
        return {"step": self._step, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        self._drain()
        self._step = int(state["step"])
        self.seed = int(state["seed"])

    # -- production ------------------------------------------------------------
    def _make(self, step: int) -> Dict[str, np.ndarray]:
        if self.cfg.family == "image":
            return image_batch(self.cfg, self.batch, seed=self.seed, step=step)
        return lm_batch(self.cfg, self.batch, self.seq_len, seed=self.seed, step=step)

    def _place(self, host_batch: Dict[str, np.ndarray]):
        shardings = batch_shardings(host_batch, self.mesh)
        if shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in host_batch.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in host_batch.items()}

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def _drain(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()
        self._stop.clear()

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        while True:
            step, host_batch = self._q.get()
            if step == self._step:                 # drop stale prefetches post-restore
                break
        self._step += 1
        return self._place(host_batch)

    def close(self):
        self._drain()
