"""Deterministic synthetic data: learnable token streams + image batches.

Token stream: a hidden-Markov-ish bigram process (each token's successor is
``perm[token]`` with probability ``1 - noise``) so a real model trains to a
loss well below uniform — used by the end-to-end training example and the
fault-tolerance tests (loss must keep descending across restarts).

Everything is a pure function of ``(seed, step)`` so a restored data iterator
reproduces the exact same batches.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["lm_batch", "image_batch", "video_frame"]


def _perm(vocab: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed ^ 0x5EED).permutation(vocab)


def lm_batch(
    cfg: ModelConfig,
    batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    step: int = 0,
    noise: float = 0.25,
) -> Dict[str, np.ndarray]:
    """Batch for any LM-family arch (adds stub-frontend inputs as needed)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
    vocab = max(cfg.vocab_size, 2)
    perm = _perm(vocab, seed)

    if cfg.family == "vlm" and cfg.frontend == "vision_stub":
        text_len = seq_len - cfg.num_patches
        assert text_len > 1, (seq_len, cfg.num_patches)
    elif cfg.family == "encdec":
        text_len = seq_len
    else:
        text_len = seq_len

    toks = np.empty((batch, text_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    flip = rng.random((batch, text_len)) < noise
    rand = rng.integers(0, vocab, (batch, text_len))
    for t in range(text_len):
        nxt = perm[toks[:, t]]
        toks[:, t + 1] = np.where(flip[:, t], rand[:, t], nxt)
    tokens, labels = toks[:, :-1], toks[:, 1:]

    out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm" and cfg.frontend == "vision_stub":
        p = cfg.num_patches
        out["patch_embeds"] = rng.standard_normal((batch, p, cfg.d_model)).astype(np.float32) * 0.02
        out["labels"] = np.concatenate(
            [np.zeros((batch, p), np.int32), labels], axis=1
        )
        out["loss_weights"] = np.concatenate(
            [np.zeros((batch, p), np.float32), np.ones_like(labels, np.float32)], axis=1
        ).astype(np.float32)
    elif cfg.family == "encdec":
        t_enc = min(cfg.encoder_len, seq_len)
        out["enc_embeds"] = rng.standard_normal((batch, t_enc, cfg.d_model)).astype(np.float32) * 0.02
    return out


def image_batch(
    cfg: ModelConfig, batch: int, *, seed: int = 0, step: int = 0
) -> Dict[str, np.ndarray]:
    """Batch of synthetic images (blocks + gradients => real edges)."""
    rng = np.random.default_rng((seed * 1_000_003 + step) & 0x7FFFFFFF)
    h, w = cfg.image_h, cfg.image_w
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    imgs = np.empty((batch, h, w), np.float32)
    for i in range(batch):
        base = 40.0 + 50.0 * np.sin(xx / rng.uniform(8, 64)) * np.cos(yy / rng.uniform(8, 64))
        cx, cy, r = rng.uniform(0, w), rng.uniform(0, h), rng.uniform(min(h, w) / 8, min(h, w) / 3)
        disk = ((xx - cx) ** 2 + (yy - cy) ** 2) < r * r
        imgs[i] = np.clip(base + 120.0 * disk + rng.normal(0, 2, (h, w)), 0, 255)
    return {"images": imgs}


def video_frame(
    cfg: ModelConfig,
    stream: int,
    step: int,
    *,
    seed: int = 0,
    motion: float = 2.0,
    noise: float = 0.0,
) -> np.ndarray:
    """One ``uint8 (H, W)`` frame of a synthetic camera stream.

    A per-stream static textured background (the same sinusoid family as
    :func:`image_batch`) with a bright disk translating ``motion`` pixels
    per step along a per-stream direction — the camera-on-a-pole workload
    for the streaming engine. ``motion=0, noise=0`` makes every frame of a
    stream bit-identical (the delta-skip best case); ``noise > 0`` adds
    per-step sensor noise (the worst case: every tile changes every frame).
    Pure function of ``(seed, stream, step)``.
    """
    h, w = cfg.image_h, cfg.image_w
    rng = np.random.default_rng((seed * 1_000_003 + stream) & 0x7FFFFFFF)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    base = 40.0 + 50.0 * np.sin(xx / rng.uniform(8, 64)) * np.cos(yy / rng.uniform(8, 64))
    r = min(h, w) / 6.0
    ang = rng.uniform(0, 2 * np.pi)
    cx = (w / 2.0 + motion * step * np.cos(ang)) % w
    cy = (h / 2.0 + motion * step * np.sin(ang)) % h
    disk = ((xx - cx) ** 2 + (yy - cy) ** 2) < r * r
    frame = base + 120.0 * disk
    if noise > 0:
        step_rng = np.random.default_rng(
            (seed * 1_000_003 + stream * 8191 + step * 131) & 0x7FFFFFFF
        )
        frame = frame + step_rng.normal(0, noise, (h, w))
    return np.clip(frame, 0, 255).astype(np.uint8)
