"""repro: multi-directional Sobel operator (Chang et al., CS.DC 2023),
TPU-native, embedded in a multi-pod JAX training/serving framework.

User-facing entry point: ``repro.api`` —
``edge_detect(images, EdgeConfig(...)) -> EdgeResult`` over the declarative
operator registry in ``repro.core.filters``."""

__version__ = "1.1.0"
