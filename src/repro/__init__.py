"""repro: multi-directional Sobel operator (Chang et al., CS.DC 2023),
TPU-native, embedded in a multi-pod JAX training/serving framework."""

__version__ = "1.0.0"
