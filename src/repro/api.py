"""repro.api — the single user-facing facade for the edge-detection stack.

One call::

    from repro.api import EdgeConfig, edge_detect

    result = edge_detect(frames, EdgeConfig(operator="scharr3"))
    result.magnitude      # (..., H, W) edge image
    result.orientation    # present when with_orientation=True
    result.components     # (..., D, H, W) when with_components=True
    result.peak           # (...,) per-image max when with_max/normalize
    result.thin           # NMS-thinned magnitude when nms=True
    result.edges          # (..., H, W) bool edge map when hysteresis=True

:class:`EdgeConfig` is one frozen dataclass — operator (any name in the
``repro.core.filters`` registry), directions, variant, padding, backend,
block overrides, and output selection — threaded verbatim through
``repro.kernels.dispatch`` down to the Pallas megakernel / XLA reference.
:class:`EdgeResult` is a structured output; both are registered pytrees, so
the facade composes with ``jax.jit``/``vmap``/sharding.

Input layout is auto-detected (``HW`` / ``HWC`` / ``NHW`` / ``NHWC`` /
batched video ``NTHW``/``NTHWC``): a trailing dimension of exactly 3 on a
>= 3-D input is treated as RGB channels; everything before the spatial
``(H, W)`` pair is batch. Pass ``layout=`` to override (e.g. a genuine
3-pixel-wide grayscale image).

The legacy entry points — ``repro.core.pipeline.edge_detect``,
``repro.kernels.dispatch.{sobel,edge_detect}``,
``repro.kernels.ops.{sobel,edge_pipeline}`` — are deprecation-warning shims
over this module and remain bit-exact with it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.filters import SobelParams, get_operator
from repro.sharding.halo import ShardConfig

__all__ = [
    "EdgeConfig",
    "EdgeResult",
    "ShardConfig",
    "edge_detect",
    "detect_layout",
    "LAYOUTS",
]

# Recognized canonical layouts, in detection order of dims.
LAYOUTS = ("HW", "HWC", "NHW", "NHWC", "NTHW", "NTHWC")


def detect_layout(shape: Tuple[int, ...]) -> str:
    """Canonical layout string for an input shape.

    Rule: a trailing dim of exactly 3 on a >= 3-D input is the RGB channel
    axis; the last two remaining dims are ``(H, W)``; every leading dim is
    batch (``N``, then ``T`` for video stacks). 2-D input is one grayscale
    image.
    """
    ndim = len(shape)
    rgb = ndim >= 3 and shape[-1] == 3
    spatial = ndim - (1 if rgb else 0)
    if spatial < 2:
        raise ValueError(f"cannot interpret shape {shape} as image(s)")
    batch = spatial - 2
    # 0/1/2 batch dims get the canonical names; deeper stacks are still
    # accepted (every leading dim is batch) under a generic "N..." prefix.
    prefix = ("", "N", "NT")[batch] if batch <= 2 else "N" * batch
    return prefix + "HW" + ("C" if rgb else "")


@dataclasses.dataclass(frozen=True)
class EdgeConfig:
    """Everything one edge-detection call needs, in one frozen value.

    Fields:
      operator:   registered operator name (``sobel5`` | ``sobel3`` |
                  ``scharr3`` | ``prewitt3`` | ``sobel7`` | custom).
      directions: direction count; 0 = the operator's maximum.
      variant:    algorithmic variant (``direct``/``separable``/``v1``/``v2``);
                  ``auto`` = the operator's best. Unsupported ladder variants
                  coerce down (all variants are mathematically identical).
      params:     custom generalized weights (Sobel-5x5 family; paper §3.2).
      padding:    boundary rule: ``reflect`` | ``edge`` | ``zero``.
      normalize:  scale magnitude into [0, 255] per image (display form).
      backend:    ``auto`` | ``pallas-tpu`` | ``pallas-interpret`` | ``xla``;
                  None = auto. Outputs are bit-exact across backends.
      block_h/block_w: Pallas tile override; None = tuning cache / default.
      shard:      :class:`~repro.sharding.halo.ShardConfig` — spread the call
                  over the image mesh ``(data, row, col)`` with halo
                  exchange between spatial neighbors; None = single device.
                  Sharded outputs are bit-exact with single-device ones.
      nms:        direction-aware non-maximum suppression: ``magnitude``
                  (and ``thin``) become the thinned edge map — suppressed
                  pixels are exactly 0. Fused into the Pallas megakernel
                  (the halo grows by one ring); bit-exact with the XLA
                  reference (``repro.core.nms``) on every backend/mesh.
      hysteresis: double-threshold + connected-edge linking on the thin map
                  (implies ``nms``); sets ``EdgeResult.edges`` (bool).
                  Linking is global, so it always runs post-gather in XLA.
      low, high:  hysteresis thresholds as *fractions of the per-image
                  magnitude peak* (scale-free across operators/inputs);
                  None = 0.10 / 0.20 (``repro.core.nms.DEFAULT_LOW/HIGH``).
      with_components:  also return per-direction gradients ``(..., D, H, W)``.
      with_orientation: also return gradient orientation ``atan2(G_y, G_x)``.
      with_max:         also return the per-image peak of the unnormalized
                        (un-thinned) magnitude (free on the fused Pallas
                        path).
    """

    operator: str = "sobel5"
    directions: int = 0
    variant: str = "auto"
    params: Optional[SobelParams] = None
    padding: str = "reflect"
    normalize: bool = True
    backend: Optional[str] = None
    block_h: Optional[int] = None
    block_w: Optional[int] = None
    shard: Optional[ShardConfig] = None
    nms: bool = False
    hysteresis: bool = False
    low: Optional[float] = None
    high: Optional[float] = None
    with_components: bool = False
    with_orientation: bool = False
    with_max: bool = False

    def replace(self, **kw) -> "EdgeConfig":
        return dataclasses.replace(self, **kw)

    def resolved(self) -> "EdgeConfig":
        """Fill ``auto``/0 fields from the operator spec and validate.

        Idempotent; raises for unknown operators, unsupported directions,
        unknown variants, or malformed hysteresis thresholds. Requesting
        ``hysteresis`` auto-enables ``nms`` (linking operates on the thin
        map) and pins concrete ``low``/``high`` fractions. The resolved
        config is what gets threaded through dispatch -> kernels (and
        recorded in :class:`EdgeResult`).
        """
        from repro.core import nms as _nms

        low, high = self.low, self.high
        if not self.hysteresis and (low is not None or high is not None):
            if (low, high) == (_nms.DEFAULT_LOW, _nms.DEFAULT_HIGH):
                # A resolved hysteresis config pinned the defaults; toggling
                # hysteresis off (e.g. edge_detect(x, cfg, hysteresis=False)
                # to reuse a detector config for magnitude) clears them.
                low = high = None
            else:
                raise ValueError(
                    "low/high are hysteresis thresholds; set hysteresis=True "
                    "(nms alone never thresholds) or leave them unset"
                )
        if self.hysteresis:
            low = _nms.DEFAULT_LOW if low is None else low
            high = _nms.DEFAULT_HIGH if high is None else high
        for name, v in (("low", low), ("high", high)):
            if v is not None and not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{name}={v} must be a fraction of the magnitude peak "
                    f"in [0, 1]"
                )
        if low is not None and high is not None and low > high:
            raise ValueError(f"low={low} must not exceed high={high}")
        spec = get_operator(self.operator, self.params)
        return self.replace(
            directions=spec.resolve_directions(self.directions),
            variant=spec.resolve_variant(self.variant),
            nms=self.nms or self.hysteresis,
            low=low,
            high=high,
        )

    @property
    def spec(self):
        return get_operator(self.operator, self.params)


# Config is pure static data — by-value (hashable) through jit, like a str.
jax.tree_util.register_static(EdgeConfig)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgeResult:
    """Structured output of :func:`edge_detect`.

    ``magnitude`` is always present; the optional fields mirror the
    ``with_*``/``nms``/``hysteresis`` output selection of
    :class:`EdgeConfig`. When ``config.nms`` is set, ``magnitude`` *is* the
    NMS-thinned map (the fused kernel emits it in one pass) and ``thin``
    aliases it; ``peak`` stays the per-image max of the un-thinned
    magnitude either way. ``layout`` is the detected (or overridden) input
    layout; ``config`` is the fully resolved :class:`EdgeConfig` that
    produced the result.
    """

    magnitude: jnp.ndarray                     # (..., H, W) f32
    components: Optional[jnp.ndarray] = None   # (..., D, H, W) f32
    orientation: Optional[jnp.ndarray] = None  # (..., H, W) f32, radians
    peak: Optional[jnp.ndarray] = None         # (...,) f32 per-image max
    thin: Optional[jnp.ndarray] = None         # (..., H, W) f32, nms=True
    edges: Optional[jnp.ndarray] = None        # (..., H, W) bool, hysteresis
    layout: str = "HW"
    config: Optional[EdgeConfig] = None

    def tree_flatten(self):
        leaves = (self.magnitude, self.components, self.orientation,
                  self.peak, self.thin, self.edges)
        return leaves, (self.layout, self.config)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        layout, config = aux
        magnitude, components, orientation, peak, thin, edges = leaves
        return cls(magnitude, components, orientation, peak, thin, edges,
                   layout, config)


def edge_detect(
    images,
    config: Optional[EdgeConfig] = None,
    *,
    layout: Optional[str] = None,
    mesh=None,
    **overrides,
) -> EdgeResult:
    """Run the full edge-detection pipeline on ``images``.

    Args:
      images: ``HW`` / ``HWC`` / ``NHW`` / ``NHWC`` grayscale or RGB images,
        or batched video stacks (``NTHW`` / ``NTHWC``); u8 or float.
      config: an :class:`EdgeConfig`; None = defaults.
      layout: explicit layout override (skips auto-detection) — the escape
        hatch for ambiguous shapes, e.g. a ``(3, H, W)`` grayscale batch
        whose trailing dim happens to be 3.
      mesh: concrete image mesh (axes ``data``/``row``/``col``) overriding
        ``config.shard`` — for callers that manage the device population
        themselves (elastic serving).
      **overrides: convenience — field overrides applied to ``config`` via
        ``dataclasses.replace`` (e.g. ``edge_detect(x, operator="scharr3")``).

    Returns:
      :class:`EdgeResult` with batch dims mirroring the input's.
    """
    from repro.kernels import dispatch

    cfg = (config or EdgeConfig())
    if overrides:
        cfg = cfg.replace(**overrides)
    cfg = cfg.resolved()
    images = jnp.asarray(images)
    layout = layout or detect_layout(images.shape)
    return dispatch.edge(images, cfg, layout=layout, mesh=mesh)
