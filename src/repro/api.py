"""repro.api — the single user-facing facade for the edge-detection stack.

One call::

    from repro.api import EdgeConfig, edge_detect

    result = edge_detect(frames, EdgeConfig(operator="scharr3"))
    result.magnitude      # (..., H, W) edge image
    result.orientation    # present when with_orientation=True
    result.components     # (..., D, H, W) when with_components=True
    result.peak           # (...,) per-image max when with_max/normalize
    result.thin           # NMS-thinned magnitude when nms=True
    result.edges          # (..., H, W) bool edge map when hysteresis=True

:class:`EdgeConfig` is one frozen dataclass — operator (any name in the
``repro.core.filters`` registry) or multi-stage :class:`StencilPlan`
(``plan="canny5"`` for the fused Gaussian5 -> Sobel5 -> NMS chain),
directions, variant, padding, backend, block overrides, and output
selection — threaded verbatim through ``repro.kernels.dispatch`` down to
the Pallas megakernel / XLA reference. :class:`EdgeResult` is a structured
output; both are registered pytrees, so the facade composes with
``jax.jit``/``vmap``/sharding.

Input layout is auto-detected (``HW`` / ``HWC`` / ``NHW`` / ``NHWC`` /
batched video ``NTHW``/``NTHWC``): a trailing dimension of exactly 3 on a
>= 3-D input is treated as RGB channels; everything before the spatial
``(H, W)`` pair is batch. Pass ``layout=`` to override (e.g. a genuine
3-pixel-wide grayscale image).

This module IS the entry point: the historical shims
(``repro.core.pipeline.edge_detect``, ``repro.kernels.dispatch.{sobel,
edge_detect}``, ``repro.kernels.ops.{sobel,edge_pipeline}``) were removed
with the stencil-platform refactor — see README "Migrating from the legacy
entry points".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.filters import SobelParams, StencilPlan, get_operator, resolve_plan
from repro.sharding.halo import ShardConfig

__all__ = [
    "EdgeConfig",
    "EdgeResult",
    "ShardConfig",
    "StreamState",
    "edge_detect",
    "edge_detect_stream",
    "detect_layout",
    "LAYOUTS",
]

# Recognized canonical layouts, in detection order of dims.
LAYOUTS = ("HW", "HWC", "NHW", "NHWC", "NTHW", "NTHWC")


def detect_layout(shape: Tuple[int, ...]) -> str:
    """Canonical layout string for an input shape.

    Rule: a trailing dim of exactly 3 on a >= 3-D input is the RGB channel
    axis; the last two remaining dims are ``(H, W)``; every leading dim is
    batch (``N``, then ``T`` for video stacks). 2-D input is one grayscale
    image.
    """
    ndim = len(shape)
    rgb = ndim >= 3 and shape[-1] == 3
    spatial = ndim - (1 if rgb else 0)
    if spatial < 2:
        raise ValueError(f"cannot interpret shape {shape} as image(s)")
    batch = spatial - 2
    # 0/1/2 batch dims get the canonical names; deeper stacks are still
    # accepted (every leading dim is batch) under a generic "N..." prefix.
    prefix = ("", "N", "NT")[batch] if batch <= 2 else "N" * batch
    return prefix + "HW" + ("C" if rgb else "")


@dataclasses.dataclass(frozen=True)
class EdgeConfig:
    """Everything one edge-detection call needs, in one frozen value.

    Fields:
      operator:   registered operator name (``sobel5`` | ``sobel3`` |
                  ``scharr3`` | ``prewitt3`` | ``sobel7`` | custom).
      plan:       multi-stage :class:`~repro.core.filters.StencilPlan` —
                  a registered plan name (``canny5`` | ``blur_sobel5``) or
                  a :class:`StencilPlan` value. The plan is the single
                  source of truth for the whole stencil chain: it
                  overrides ``operator`` (the resolved config pins
                  ``operator`` to the plan's gradient stage), composes the
                  halo from every stage radius, and — when it ends in an
                  ``nms`` stage — forces ``nms=True``. The entire chain
                  runs as ONE fused Pallas launch (or the equivalent
                  staged XLA reference), bit-exact across backends/meshes.
      directions: direction count; 0 = the operator's maximum.
      variant:    algorithmic variant (``direct``/``separable``/``v1``/``v2``);
                  ``auto`` = the operator's best. Unsupported ladder variants
                  coerce down (all variants are mathematically identical).
      params:     custom generalized weights (Sobel-5x5 family; paper §3.2).
      padding:    boundary rule: ``reflect`` | ``edge`` | ``zero``.
      normalize:  scale magnitude into [0, 255] per image (display form).
      backend:    ``auto`` | ``pallas-tpu`` | ``pallas-interpret`` | ``xla``;
                  None = auto. Outputs are bit-exact across backends.
      block_h/block_w: Pallas tile override; None = tuning cache / default.
      precision:  arithmetic lane: ``auto`` | ``f32`` | ``int``. ``int`` is
                  the exact low-precision lane — u8 gray frames x integer
                  taps accumulated in the i16/i32 budget
                  ``repro.core.ladder`` proves, f32 only from the
                  magnitude/NMS stage on — *bit-identical* to the f32 lane
                  (it raises when the proof does not cover the workload:
                  RGB, non-u8 input, fractional taps, oversized bound).
                  ``auto`` opts eligible workloads in on the Pallas
                  backends and stays f32 on XLA
                  (``repro.kernels.dispatch.resolve_precision``).
      pipeline_depth: HBM->VMEM pipelining of the Pallas kernel's input
                  windows. None = automatic (Pallas double buffering, or a
                  tuned depth from the cache); 2..8 = an explicit manual
                  DMA ring of that depth — tile k+1's halo load overlaps
                  tile k's compute under kernel control (DESIGN.md §11).
                  Outputs are bit-exact across depths; ignored on the XLA
                  backend (no DMA to pipeline).
      shard:      :class:`~repro.sharding.halo.ShardConfig` — spread the call
                  over the image mesh ``(data, row, col)`` with halo
                  exchange between spatial neighbors; None = single device.
                  Sharded outputs are bit-exact with single-device ones.
      nms:        direction-aware non-maximum suppression: ``magnitude``
                  (and ``thin``) become the thinned edge map — suppressed
                  pixels are exactly 0. Fused into the Pallas megakernel
                  (the halo grows by one ring); bit-exact with the XLA
                  reference (``repro.core.nms``) on every backend/mesh.
      hysteresis: double-threshold + connected-edge linking on the thin map
                  (implies ``nms``); sets ``EdgeResult.edges`` (bool).
                  Linking is global, so it always runs post-gather in XLA.
      low, high:  hysteresis thresholds as *fractions of the per-image
                  magnitude peak* (scale-free across operators/inputs);
                  None = 0.10 / 0.20 (``repro.core.nms.DEFAULT_LOW/HIGH``).
      temporal:   temporal hysteresis for video streams (implies
                  ``hysteresis``): edges detected in recent frames seed the
                  current frame's linking wherever the current thin map is
                  at least weak, so detections persist instead of
                  flickering. Streaming-only — carried per-stream state, so
                  plain :func:`edge_detect` rejects it; use
                  :func:`edge_detect_stream` / ``repro.serve.streams``.
      decay:      per-frame geometric decay of the temporal seed strength
                  in [0, 1]: a past edge keeps seeding while
                  ``decay^age > TEMPORAL_FLOOR`` (``repro.core.nms``).
                  ``decay=0`` makes streaming output bit-identical to
                  stateless per-frame detection (the tested contract).
      with_components:  also return per-direction gradients ``(..., D, H, W)``.
      with_orientation: also return gradient orientation ``atan2(G_y, G_x)``.
      with_max:         also return the per-image peak of the unnormalized
                        (un-thinned) magnitude (free on the fused Pallas
                        path).
    """

    operator: str = "sobel5"
    plan: "str | StencilPlan | None" = None
    directions: int = 0
    variant: str = "auto"
    params: Optional[SobelParams] = None
    padding: str = "reflect"
    normalize: bool = True
    backend: Optional[str] = None
    block_h: Optional[int] = None
    block_w: Optional[int] = None
    precision: str = "auto"
    pipeline_depth: Optional[int] = None
    shard: Optional[ShardConfig] = None
    nms: bool = False
    hysteresis: bool = False
    low: Optional[float] = None
    high: Optional[float] = None
    temporal: bool = False
    decay: float = 0.0
    with_components: bool = False
    with_orientation: bool = False
    with_max: bool = False

    def replace(self, **kw) -> "EdgeConfig":
        return dataclasses.replace(self, **kw)

    def resolved(self) -> "EdgeConfig":
        """Fill ``auto``/0 fields from the operator spec and validate.

        Idempotent; raises for unknown operators, unsupported directions,
        unknown variants, or malformed hysteresis thresholds. Requesting
        ``hysteresis`` auto-enables ``nms`` (linking operates on the thin
        map) and pins concrete ``low``/``high`` fractions. The resolved
        config is what gets threaded through dispatch -> kernels (and
        recorded in :class:`EdgeResult`).
        """
        from repro.core import nms as _nms

        if self.precision not in ("auto", "f32", "int"):
            raise ValueError(
                f"unknown precision {self.precision!r}; expected 'auto', "
                "'f32' or 'int'"
            )
        if self.pipeline_depth is not None and not (
            isinstance(self.pipeline_depth, int)
            and 2 <= self.pipeline_depth <= 8
        ):
            raise ValueError(
                f"pipeline_depth must be None (automatic) or an int in "
                f"2..8 (manual DMA ring depth), got {self.pipeline_depth!r}"
            )
        if not 0.0 <= self.decay <= 1.0:
            raise ValueError(
                f"decay={self.decay} must be a per-frame attenuation in [0, 1]"
            )
        if self.decay and not self.temporal:
            raise ValueError(
                "decay is the temporal-hysteresis attenuation; set "
                "temporal=True (stateless calls carry no seed state) or "
                "leave it 0"
            )
        hysteresis = self.hysteresis or self.temporal
        low, high = self.low, self.high
        if not hysteresis and (low is not None or high is not None):
            if (low, high) == (_nms.DEFAULT_LOW, _nms.DEFAULT_HIGH):
                # A resolved hysteresis config pinned the defaults; toggling
                # hysteresis off (e.g. edge_detect(x, cfg, hysteresis=False)
                # to reuse a detector config for magnitude) clears them.
                low = high = None
            else:
                raise ValueError(
                    "low/high are hysteresis thresholds; set hysteresis=True "
                    "(nms alone never thresholds) or leave them unset"
                )
        if hysteresis:
            low = _nms.DEFAULT_LOW if low is None else low
            high = _nms.DEFAULT_HIGH if high is None else high
        for name, v in (("low", low), ("high", high)):
            if v is not None and not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{name}={v} must be a fraction of the magnitude peak "
                    "in [0, 1]"
                )
        if low is not None and high is not None and low > high:
            raise ValueError(f"low={low} must not exceed high={high}")
        plan = resolve_plan(self.plan)
        if plan is not None:
            spec = plan.gradient
            if spec is None:
                raise ValueError(
                    f"plan {plan.name!r} has no gradient stage; the edge "
                    "engine emits direction components (append a gradient "
                    "operator stage)"
                )
            if (self.nms or hysteresis) and not plan.nms:
                raise ValueError(
                    f"plan gate 'nms-stage': plan {plan.name!r} has no "
                    "trailing 'nms' stage but nms/hysteresis was requested; "
                    "the plan is the single source of truth — append 'nms' "
                    "to its stages"
                )
            operator = spec.name
            nms = plan.nms or hysteresis
        else:
            spec = get_operator(self.operator, self.params)
            operator = self.operator
            nms = self.nms or hysteresis
        return self.replace(
            plan=plan,
            operator=operator,
            directions=spec.resolve_directions(self.directions),
            variant=spec.resolve_variant(self.variant),
            nms=nms,
            hysteresis=hysteresis,
            low=low,
            high=high,
        )

    @property
    def spec(self):
        plan = resolve_plan(self.plan)
        if plan is not None and plan.gradient is not None:
            return plan.gradient
        return get_operator(self.operator, self.params)


# Config is pure static data — by-value (hashable) through jit, like a str.
jax.tree_util.register_static(EdgeConfig)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EdgeResult:
    """Structured output of :func:`edge_detect`.

    ``magnitude`` is always present; the optional fields mirror the
    ``with_*``/``nms``/``hysteresis`` output selection of
    :class:`EdgeConfig`. When ``config.nms`` is set, ``magnitude`` *is* the
    NMS-thinned map (the fused kernel emits it in one pass) and ``thin``
    aliases it; ``peak`` stays the per-image max of the un-thinned
    magnitude either way. ``layout`` is the detected (or overridden) input
    layout; ``config`` is the fully resolved :class:`EdgeConfig` that
    produced the result.
    """

    magnitude: jnp.ndarray                     # (..., H, W) f32
    components: Optional[jnp.ndarray] = None   # (..., D, H, W) f32
    orientation: Optional[jnp.ndarray] = None  # (..., H, W) f32, radians
    peak: Optional[jnp.ndarray] = None         # (...,) f32 per-image max
    thin: Optional[jnp.ndarray] = None         # (..., H, W) f32, nms=True
    edges: Optional[jnp.ndarray] = None        # (..., H, W) bool, hysteresis
    skipped: Optional[jnp.ndarray] = None      # (...,) i32 delta-skipped tiles
    layout: str = "HW"
    config: Optional[EdgeConfig] = None

    def tree_flatten(self):
        leaves = (self.magnitude, self.components, self.orientation,
                  self.peak, self.thin, self.edges, self.skipped)
        return leaves, (self.layout, self.config)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        layout, config = aux
        (magnitude, components, orientation, peak, thin, edges,
         skipped) = leaves
        return cls(magnitude, components, orientation, peak, thin, edges,
                   skipped, layout, config)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StreamState:
    """Per-stream temporal state carried between frames of one video stream.

    The leaves cache exactly what the delta-skip and temporal-hysteresis
    machinery needs from frame ``t - 1`` (all batched ``(B, ...)`` — one
    slice per stream when the engine batches same-resolution streams):

      * ``frame``   — the previous input frames in kernel dtype (u8 stays
        u8), the reference for the exact per-tile change test.
      * ``primary`` — the previous *un-normalized* primary map (the NMS
        thin magnitude when ``nms``, else the magnitude): the splice source
        for delta-skipped tiles.
      * ``bmax``    — the previous per-block maxima ``(B, gh, gw)``: cached
        SMEM output of the fused kernel, spliced per-tile so the global
        peak (normalization + hysteresis thresholds) stays exact.
      * ``seed``    — the temporal seed-strength map (``config.temporal``;
        ``None`` otherwise): 1.0 at last frame's edges, geometrically
        decayed elsewhere (``repro.core.nms.update_seed_strength``).

    ``block`` (static aux) pins the ``(block_h, block_w)`` delta-tile grid
    so every frame of a stream tiles identically — a mid-stream tuning
    change cannot silently misalign the cached ``bmax``/mask grids.
    ``initialized`` is ``False`` for the zero state :func:`init` returns;
    the first frame then recomputes every tile regardless of the (zero)
    ``frame`` cache.
    """

    frame: Optional[jnp.ndarray]
    primary: Optional[jnp.ndarray]
    bmax: Optional[jnp.ndarray]
    seed: Optional[jnp.ndarray]
    block: Tuple[int, int] = (0, 0)
    initialized: bool = False

    def tree_flatten(self):
        return ((self.frame, self.primary, self.bmax, self.seed),
                (self.block, self.initialized))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        block, initialized = aux
        frame, primary, bmax, seed = leaves
        return cls(frame, primary, bmax, seed, block, initialized)

    @property
    def grid(self) -> Tuple[int, int]:
        """(gh, gw) delta-tile grid of the cached ``bmax``."""
        return self.bmax.shape[-2], self.bmax.shape[-1]

    @property
    def tiles(self) -> int:
        """Total delta tiles per frame (the denominator for skip rates)."""
        gh, gw = self.grid
        return gh * gw

    @classmethod
    def init(cls, batch, h, w, config: "EdgeConfig", *, rgb: bool = False,
             dtype=jnp.uint8) -> "StreamState":
        """Zero state for ``batch`` streams of ``(h, w)`` frames.

        The first :func:`edge_detect_stream` call on it recomputes every
        tile (``initialized=False`` forces an all-changed mask), filling
        the caches; callers never need to special-case frame 0.
        """
        from repro.kernels import dispatch

        config = config.resolved()
        bh, bw = dispatch.stream_block_shape(h, w, config, rgb=rgb)
        gh, gw = -(-h // bh), -(-w // bw)
        shape = (batch, h, w, 3) if rgb else (batch, h, w)
        return cls(
            frame=jnp.zeros(shape, dtype),
            primary=jnp.zeros((batch, h, w), jnp.float32),
            bmax=jnp.zeros((batch, gh, gw), jnp.float32),
            seed=(jnp.zeros((batch, h, w), jnp.float32)
                  if config.temporal else None),
            block=(bh, bw),
            initialized=False,
        )


def edge_detect(
    images,
    config: Optional[EdgeConfig] = None,
    *,
    layout: Optional[str] = None,
    mesh=None,
    **overrides,
) -> EdgeResult:
    """Run the full edge-detection pipeline on ``images``.

    Args:
      images: ``HW`` / ``HWC`` / ``NHW`` / ``NHWC`` grayscale or RGB images,
        or batched video stacks (``NTHW`` / ``NTHWC``); u8 or float.
      config: an :class:`EdgeConfig`; None = defaults.
      layout: explicit layout override (skips auto-detection) — the escape
        hatch for ambiguous shapes, e.g. a ``(3, H, W)`` grayscale batch
        whose trailing dim happens to be 3.
      mesh: concrete image mesh (axes ``data``/``row``/``col``) overriding
        ``config.shard`` — for callers that manage the device population
        themselves (elastic serving).
      **overrides: convenience — field overrides applied to ``config`` via
        ``dataclasses.replace`` (e.g. ``edge_detect(x, operator="scharr3")``).

    Returns:
      :class:`EdgeResult` with batch dims mirroring the input's.
    """
    from repro.kernels import dispatch

    cfg = (config or EdgeConfig())
    if overrides:
        cfg = cfg.replace(**overrides)
    cfg = cfg.resolved()
    images = jnp.asarray(images)
    layout = layout or detect_layout(images.shape)
    return dispatch.edge(images, cfg, layout=layout, mesh=mesh)


def edge_detect_stream(
    frames,
    config: Optional[EdgeConfig] = None,
    state: Optional[StreamState] = None,
    *,
    layout: Optional[str] = None,
    **overrides,
) -> Tuple[EdgeResult, StreamState]:
    """One frame step of the stateful streaming pipeline.

    ``frames`` is ONE frame per stream — ``HW`` / ``HWC`` for a single
    stream or ``NHW`` / ``NHWC`` for a batch of same-resolution streams
    (no video-stack ``T`` axis: time is the successive calls). ``state``
    is the previous call's :class:`StreamState` (``None`` = cold start).

    Returns ``(result, new_state)``. On top of the stateless pipeline the
    streaming path adds:

      * **Delta-skip tiles** — a per-tile exact change test against
        ``state.frame``; unchanged tiles splice the cached thin map and
        per-block maxima instead of recomputing (``result.skipped`` counts
        them per stream). Output is bit-identical to full recompute.
      * **Temporal hysteresis** — with ``config.temporal``, recent frames'
        edges seed this frame's linking (decayed by ``config.decay``), so
        detections persist instead of flickering. ``decay=0`` is
        bit-identical to stateless per-frame :func:`edge_detect`.

    The call is fully traceable (``jax.jit`` over ``(frames, state)`` with
    the config static); ``repro.serve.streams.StreamEngine`` is the
    slot/admission scheduler that drives it for many concurrent streams.
    """
    from repro.kernels import dispatch

    cfg = (config or EdgeConfig())
    if overrides:
        cfg = cfg.replace(**overrides)
    cfg = cfg.resolved()
    frames = jnp.asarray(frames)
    layout = layout or detect_layout(frames.shape)
    return dispatch.edge_stream(frames, cfg, state, layout=layout)
