"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "constant"]


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
    t = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = final_frac * peak_lr + (1.0 - final_frac) * peak_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)
