"""AdamW with global-norm clipping and ZeRO-1 optimizer-state sharding.

Functional API (optax-style but self-contained):
    state = init(params)
    new_params, new_state, stats = update(grads, state, params, lr, ...)

ZeRO-1: ``opt_state_axes`` augments each parameter's logical axes so the m/v
moments shard their largest unsharded dim over ``data``. Inside a single jit
train step GSPMD then materializes the classic ZeRO-1 schedule: grads are
reduce-scattered to data shards, moment updates run sharded, and updated
params are all-gathered.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "init", "update", "opt_state_axes", "global_norm"]


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def _upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [_upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(count, new_m, new_v), {"grad_norm": gnorm}


def opt_state_axes(param_axes: Any, param_shapes: Any, mesh) -> AdamWState:
    """Logical axes for AdamWState: params' axes + ZeRO-1 `data` sharding on
    the largest dim that is still unsharded and divisible by |data|."""

    data_size = 1
    for name in ("data",):
        if name in mesh.axis_names:
            data_size *= mesh.shape[name]

    from repro.sharding.rules import get_rules

    train_rules = get_rules("train")

    def _unmapped(name) -> bool:
        if name is None:
            return True
        opts = train_rules.get(name, ())
        return not any(opts)

    def zero1(axes, shape):
        axes = list(axes)
        if data_size > 1:
            order = sorted(range(len(shape.shape)), key=lambda i: -shape.shape[i])
            for i in order:
                if _unmapped(axes[i]) and shape.shape[i] % data_size == 0:
                    axes[i] = "zero1"
                    break
        return tuple(axes)

    is_axes = lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)
    moment_axes = jax.tree.map(zero1, param_axes, param_shapes, is_leaf=is_axes)
    return AdamWState(count=(), mu=moment_axes, nu=moment_axes)
