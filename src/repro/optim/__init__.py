from repro.optim import adamw  # noqa: F401
from repro.optim.adamw import AdamWState, global_norm, opt_state_axes  # noqa: F401
from repro.optim.compress import compress_tree_psum, compressed_psum, init_error_state  # noqa: F401
from repro.optim.schedule import constant, warmup_cosine  # noqa: F401
