"""Gradient compression for cross-pod all-reduce: int8 quantized psum with
error feedback (the classic 1-bit-Adam/QSGD-style distributed-optimization
trick, adapted to jax collectives).

Used inside ``shard_map`` over the data-parallel axes; the main GSPMD path
remains uncompressed (XLA reduces in the gradient dtype).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "compress_tree_psum", "init_error_state"]


def compressed_psum(x: jax.Array, axis_name, *, bits: int = 8) -> jax.Array:
    """All-reduce ``x`` over ``axis_name`` in ``bits``-bit fixed point.

    Scale = global max|x| (one cheap f32 all-reduce), then the payload moves
    as int8/int16 (int32 accumulate — overflow-free for <= 2^(31-bits) ranks).
    """
    levels = float(2 ** (bits - 1) - 1)
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.round(x.astype(jnp.float32) / scale * levels)
    itype = jnp.int8 if bits <= 8 else jnp.int16
    q = jnp.clip(q, -levels, levels).astype(itype)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * (scale / levels)


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_tree_psum(
    grads: Any, error: Any, axis_name, *, bits: int = 8
) -> Tuple[Any, Any]:
    """Error-feedback compressed all-reduce over a gradient tree.

    Returns (reduced_grads, new_error): the quantization residual is carried
    and re-injected next step, so the compression bias telescopes away.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        reduced = compressed_psum(corrected, axis_name, bits=bits)
        n = jax.lax.psum(1, axis_name)
        # local residual: what this rank failed to communicate
        levels = float(2 ** (bits - 1) - 1)
        scale = jax.lax.pmax(jnp.max(jnp.abs(corrected)).astype(jnp.float32), axis_name)
        scale = jnp.maximum(scale, 1e-30)
        sent = jnp.round(corrected / scale * levels)
        sent = jnp.clip(sent, -levels, levels) * (scale / levels)
        new_e = corrected - sent
        return reduced / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([o[0] for o in out]), treedef.unflatten([o[1] for o in out])
