"""§Perf hillclimbing driver: lower ONE cell with optional config/sharding
overrides, compile, and print the three roofline terms + deltas vs baseline.

    PYTHONPATH=src python experiments/hillclimb.py qwen3-moe-30b-a3b train_4k \
        --variant attn_dp
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

from repro.configs import get_config
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import model_flops
from repro.roofline.constants import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.roofline.hlo import module_cost
from repro.sharding.rules import get_rules

# --- named experiment variants (hypothesis -> concrete override) -------------

def _variant(arch, shape, name):
    """Returns (cfg, rules) for a named hillclimb variant."""
    cfg = get_config(arch)
    kind = "train" if shape.startswith("train") else "serve"
    base_rules = dict(get_rules(kind))
    if name == "baseline":
        return cfg, None
    if name == "attn_dp":
        # replicate attention weights (no TP for attention); experts/mlp keep TP
        rules = dict(base_rules)
        rules["heads"] = ()
        rules["kv_heads"] = ()
        return cfg, rules
    if name == "no_tp":
        # fully batch-parallel: no model-axis sharding of any weight
        rules = dict(base_rules)
        for k in ("heads", "kv_heads", "mlp", "vocab", "embed_td", "ssm_inner",
                  "ssm_heads", "qk_rank", "kv_rank"):
            rules[k] = ()
        return cfg, rules
    if name == "experts_only_tp":
        rules = dict(base_rules)
        for k in ("heads", "kv_heads", "vocab", "embed_td"):
            rules[k] = ()
        return cfg, rules
    if name == "scan_bf16":
        return cfg.replace(ssm_scan_dtype="bfloat16"), None
    if name == "scan_bf16_chunk128":
        return cfg.replace(ssm_scan_dtype="bfloat16", ssm_chunk=128), None
    if name == "rows_dp":
        # pure data-parallel images (no row sharding -> no halo exchange)
        rules = dict(base_rules)
        rules["height"] = ()
        return cfg, rules
    if name.startswith("variant_"):
        return cfg.replace(sobel_variant=name.split("_", 1)[1]), None
    if name == "mb8":
        return cfg, None  # microbatches handled in dryrun; placeholder
    if name == "chunk4":
        return cfg.replace(ssm_chunk=4), None
    if name == "chunk8":
        return cfg.replace(ssm_chunk=8), None
    if name == "chunk16":
        return cfg.replace(ssm_chunk=16), None
    if name == "chunk32":
        return cfg.replace(ssm_chunk=32), None
    if name == "chunk64":
        return cfg.replace(ssm_chunk=64), None
    if name == "chunk128":
        return cfg.replace(ssm_chunk=128), None
    if name == "chunk512":
        return cfg.replace(ssm_chunk=512), None
    if name == "remat_dots":
        return cfg.replace(remat_policy="dots"), None
    if name == "group8k":
        return cfg.replace(moe_group_size=8192), None
    if name == "group2k":
        return cfg.replace(moe_group_size=2048), None
    if name == "capacity1":
        return cfg.replace(moe_capacity_factor=1.0), None
    if name.startswith("sobel_"):
        return cfg.replace(sobel_variant=name.split("_", 1)[1]), None
    raise KeyError(name)


def run(arch, shape, variant, mesh_name="single_pod", out_dir="experiments/perf"):
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
    chips = 512 if mesh_name == "multi_pod" else 256
    cfg, rules = _variant(arch, shape, variant)
    t0 = time.time()
    lowered = lower_cell(arch, shape, mesh, cfg=cfg, rules=rules)
    compiled = lowered.compile()
    dt = time.time() - t0
    mc = module_cost(compiled.as_text())
    mem = compiled.memory_analysis()
    kind = "train" if shape.startswith("train") else ("image" if arch == "sobel-hd" else "serve")
    mf = model_flops(arch, shape, "train" if kind == "train" else ("image" if kind == "image" else ("decode" if "decode" in shape or "long" in shape else "prefill")))

    flops_dev = max(mc["flops"], mf["model_flops"] / chips)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": mc["bytes_fused"] / HBM_BW,
        "collective_s": mc["collective_bytes"].get("total_bf16_wire", 0.0) / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    extra = {"memory_upper_s": mc["bytes"] / HBM_BW}
    ideal = mf["model_flops"] / (chips * PEAK_FLOPS_BF16)
    rec = {
        "arch": arch, "shape": shape, "variant": variant, "mesh": mesh_name,
        **{k: round(v, 6) for k, v in terms.items()},
        **{k: round(v, 6) for k, v in extra.items()},
        "dominant": dominant,
        "mfu_proxy": round(ideal / max(terms.values()), 4),
        "hbm_gb": round((mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30, 2),
        "hbm_gb_tpu_est": round((mem.argument_size_in_bytes + mem.temp_size_in_bytes / 2) / 2**30, 2),
        "collectives_gb": {k: round(v / 2**30, 2) for k, v in mc["collective_bytes"].items()},
        "compile_s": round(dt, 1),
    }
    path = os.path.join(out_dir, f"{arch}__{shape}__{variant}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    run(args.arch, args.shape, args.variant, args.mesh)
