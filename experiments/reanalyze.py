"""Recompute parsed_cost/collective_bytes in dry-run JSONs from the stored
compiled HLO (.hlo.gz) — lets the roofline evolve without recompiling.

    PYTHONPATH=src python experiments/reanalyze.py [experiments/dryrun]
"""
import glob
import gzip
import json
import os
import sys

from repro.roofline.hlo import module_cost


def main(dryrun_dir="experiments/dryrun"):
    n = 0
    for jf in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(jf))
        if rec.get("status") != "ok":
            continue
        hf = jf.replace(".json", ".hlo.gz")
        if not os.path.exists(hf):
            print(f"[no hlo] {jf}")
            continue
        with gzip.open(hf, "rt") as z:
            txt = z.read()
        mc = module_cost(txt)
        rec["parsed_cost"] = {k: v for k, v in mc.items() if k != "collective_bytes"}
        rec["collective_bytes"] = mc["collective_bytes"]
        with open(jf, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main(*sys.argv[1:])
