"""Batched serving with continuous batching: submit a wave of prompts, decode
them through the slotted engine, verify against per-request greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve import Engine, Request


def main():
    cfg = get_config("llama3.2-1b", smoke=True).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.name} ({model.param_count():,} params), 4 slots")

    engine = Engine(cfg, params, max_batch=4, max_len=128, prompt_buckets=(8, 16, 32))
    rng = np.random.default_rng(0)
    n_req = 10
    t0 = time.perf_counter()
    for uid in range(n_req):
        plen = int(rng.integers(2, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=12))
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"completed {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on 1 CPU core)")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        print(f"  req{r.uid}: {r.output}")


if __name__ == "__main__":
    main()
