"""Distributed edge-detection service (the paper's workload at pod scale).

Shards an image batch across whatever devices exist (batch -> data, rows ->
model via GSPMD halo exchange) and runs the fused pipeline for any
registered operator through one ``repro.api.EdgeConfig``. On this CPU
container the mesh is 1x1; on a pod the identical code spans (data, model)
— the dry-run proves the 256/512-chip lowering.

    PYTHONPATH=src python examples/edge_service.py --batch 8 --size 512
    PYTHONPATH=src python examples/edge_service.py --operator scharr3
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import EdgeConfig
from repro.configs import get_config
from repro.core.pipeline import make_sharded_edge_fn
from repro.data.synthetic import image_batch
from repro.runtime.elastic import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--size", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--operator", default="sobel5",
                    help="registered operator name (sobel5/scharr3/sobel7/...)")
    args = ap.parse_args()

    mesh = make_mesh(model_parallel=1)
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} device(s)")
    cfg = get_config("sobel-hd").replace(image_h=args.size, image_w=args.size)
    imgs = jnp.asarray(image_batch(cfg, args.batch)["images"])

    edge_cfg = EdgeConfig(operator=args.operator, normalize=False)
    edge_fn = make_sharded_edge_fn(mesh, edge_cfg)
    out = edge_fn(imgs)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(args.iters):
        out = edge_fn(imgs)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / args.iters
    mps = args.batch * args.size**2 / 1e6 / dt
    print(f"edges {out.shape} [{args.operator}]: {dt*1e3:.1f} ms/batch = "
          f"{mps:.1f} MPS (paper Table 2 metric)")


if __name__ == "__main__":
    main()
