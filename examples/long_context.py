"""Long-context decode with an attention-free SSM: O(1) state per token.

The assigned ``long_500k`` shape is runnable only for sub-quadratic archs
(falcon-mamba, zamba2). This demo decodes a (smoke-scale) falcon-mamba model
far past any attention window and shows the per-token cost and state size
stay constant — the property the 500k-cell dry-run exercises at scale.

    PYTHONPATH=src python examples/long_context.py --tokens 512
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=512)
    args = ap.parse_args()

    cfg = get_config("falcon-mamba-7b", smoke=True).replace(dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    print(f"{cfg.name}: {model.param_count():,} params, attention-free (mamba-1)")

    cache = model.init_cache(1, 8, dtype=jnp.float32)   # max_len is irrelevant: state is O(1)
    state_bytes = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(cache))
    print(f"recurrent state: {state_bytes/1024:.1f} KB — independent of context length")

    decode = jax.jit(model.decode_step)
    tok = jnp.zeros((1, 1), jnp.int32)
    logits, cache = decode(params, cache, tok, jnp.int32(0))  # compile
    jax.block_until_ready(logits)

    marks = {}
    t0 = time.perf_counter()
    for i in range(1, args.tokens + 1):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = decode(params, cache, tok, jnp.int32(i))
        if i in (args.tokens // 4, args.tokens // 2, args.tokens):
            jax.block_until_ready(logits)
            marks[i] = (time.perf_counter() - t0) / i * 1e3
    for pos, ms in marks.items():
        print(f"  position {pos:6d}: {ms:.2f} ms/token (cumulative mean)")
    print("per-token cost flat in context length ✓ (full-attention decode would grow linearly)")


if __name__ == "__main__":
    main()
