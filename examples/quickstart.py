"""Quickstart: four-directional 5x5 Sobel edge detection in three lines.

Runs the whole paper pipeline (gray -> pad -> fused multi-directional Sobel
-> RSS magnitude) on synthetic images, compares all four kernel variants, and
checks them against the Pallas kernel (interpret mode on CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SobelParams, edge_detect, ssim
from repro.data.synthetic import image_batch
from repro.kernels import sobel as sobel_kernel


def main():
    cfg = get_config("sobel-hd", smoke=True).replace(image_h=256, image_w=256)
    images = jnp.asarray(image_batch(cfg, batch=2)["images"])
    print(f"input batch: {images.shape} {images.dtype}")

    # --- the three-liner ---
    edges = edge_detect(images, size=5, directions=4, variant="v2")
    print(f"edges: {edges.shape}, max={float(edges.max()):.1f}")

    # --- variant ladder agreement (paper Fig. 7 check) ---
    ref = edge_detect(images, variant="direct", normalize=False)
    for variant in ("separable", "v1", "v2"):
        out = edge_detect(images, variant=variant, normalize=False)
        s = float(jnp.mean(ssim(out, ref)))
        print(f"variant {variant:10s}: SSIM vs naive = {s:.6f}")

    # --- fused Pallas kernel (TPU target; interpret-validated on CPU) ---
    kern = sobel_kernel(images, variant="v2", block_h=64)
    err = float(jnp.max(jnp.abs(kern - ref)))
    print(f"pallas kernel max |err| vs naive reference: {err:.2e}")

    # --- generalized weights (paper §3.2) ---
    custom = edge_detect(images, params=SobelParams(a=1, b=3, m=8, n=4))
    print(f"custom-weight edges: max={float(custom.max()):.1f}")


if __name__ == "__main__":
    main()
