"""Quickstart: multi-directional edge detection through the repro.api facade.

One entry point, one frozen config, one structured result: runs the paper's
four-directional 5x5 RG-v2 pipeline, swaps in other registered operators
(Scharr / Prewitt / extended 7x7 Sobel), compares the kernel-variant ladder,
and cross-checks the fused Pallas megakernel against pure XLA (interpret
mode on CPU) — bit-exact.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.api import EdgeConfig, edge_detect
from repro.configs import get_config
from repro.core import SobelParams, list_operators, ssim
from repro.data.synthetic import image_batch


def main():
    cfg = get_config("sobel-hd", smoke=True).replace(image_h=256, image_w=256)
    images = jnp.asarray(image_batch(cfg, batch=2)["images"])
    print(f"input batch: {images.shape} {images.dtype}")

    # --- the three-liner ---
    result = edge_detect(images, EdgeConfig(operator="sobel5"))
    print(f"edges: {result.magnitude.shape}, layout={result.layout}, "
          f"max={float(result.magnitude.max()):.1f}")

    # --- structured outputs: components, orientation, per-image peak ---
    rich = edge_detect(images, EdgeConfig(
        with_components=True, with_orientation=True, with_max=True))
    print(f"components: {rich.components.shape}, "
          f"orientation in [{float(rich.orientation.min()):.2f}, "
          f"{float(rich.orientation.max()):.2f}] rad, peaks={rich.peak}")

    # --- the whole operator registry through the same call ---
    for op in list_operators():
        out = edge_detect(images, EdgeConfig(operator=op, normalize=False))
        print(f"operator {op:10s}: resolved variant={out.config.variant}, "
              f"directions={out.config.directions}, "
              f"mean={float(out.magnitude.mean()):.1f}")

    # --- variant ladder agreement (paper Fig. 7 check) ---
    ref = edge_detect(images, EdgeConfig(variant="direct", normalize=False))
    for variant in ("separable", "v1", "v2"):
        out = edge_detect(images, EdgeConfig(variant=variant, normalize=False))
        s = float(jnp.mean(ssim(out.magnitude, ref.magnitude)))
        print(f"variant {variant:10s}: SSIM vs naive = {s:.6f}")

    # --- fused Pallas megakernel (TPU target; interpret-validated on CPU) ---
    kern = edge_detect(images, EdgeConfig(
        normalize=False, backend="pallas-interpret", block_h=64))
    err = float(jnp.max(jnp.abs(kern.magnitude - ref.magnitude)))
    print(f"pallas kernel max |err| vs naive reference: {err:.2e}")

    # --- generalized weights (paper §3.2) ---
    custom = edge_detect(images, EdgeConfig(params=SobelParams(a=1, b=3, m=8, n=4)))
    print(f"custom-weight edges: max={float(custom.magnitude.max()):.1f}")


if __name__ == "__main__":
    main()
