"""End-to-end training driver: train an LM on the synthetic bigram stream
with checkpointing, fault tolerance, and straggler monitoring.

Default is a ~100M-param llama-style model for a few hundred steps (the
assignment's end-to-end scenario); ``--preset tiny`` runs a CPU-friendly
smoke in under a minute.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --steps 300        # ~100M
"""
import argparse
import logging
import tempfile

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import DataLoader
from repro.models import Model
from repro.train import TrainConfig, Trainer

PRESETS = {
    "tiny": dict(
        cfg=lambda: get_config("llama3.2-1b", smoke=True),
        tc=TrainConfig(batch=8, seq_len=64, steps=30, peak_lr=5e-3, warmup_steps=5,
                       checkpoint_every=10, log_every=5),
    ),
    "100m": dict(
        cfg=lambda: ModelConfig(
            name="llama-100m", family="dense", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, rope_theta=10_000.0,
        ),
        tc=TrainConfig(batch=8, seq_len=512, steps=300, peak_lr=3e-4,
                       warmup_steps=30, checkpoint_every=100, log_every=10),
    ),
}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir (default: fresh tmp dir; pass a path to test resume)")
    args = ap.parse_args()

    preset = PRESETS[args.preset]
    cfg = preset["cfg"]()
    tc = preset["tc"]
    if args.steps:
        tc.steps = args.steps
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_train_")

    print(f"model: {cfg.name}  params={Model(cfg).param_count():,}")
    trainer = Trainer(cfg, tc)
    loader = DataLoader(cfg, tc.batch, tc.seq_len, seed=0)
    manager = CheckpointManager(ckpt_dir, keep=2, async_save=True)
    hist = trainer.fit(loader, manager=manager)
    manager.wait()
    if not hist["loss"]:
        print(f"nothing to do: checkpoint at {ckpt_dir} is already past --steps")
        return
    print(f"final loss: {hist['loss'][-1]:.4f} (start {hist['loss'][0]:.4f})")
    print(f"step-time median: {trainer.monitor.fleet_median()*1e3:.1f} ms")


if __name__ == "__main__":
    main()
